"""CI benchmark-regression gate.

Compares a freshly produced BENCH_*.json (from ``fmax_suite.py --json`` or
``throughput.py --json``) against the committed baseline under
``benchmarks/baselines/`` and exits nonzero when the headline metrics
regress beyond tolerance:

* fmax suite: average optimized fmax must not drop more than ``--tol``
  relative to baseline; no simulated deadlocks; no throughput violations;
  and (for subset runs, i.e. the CI fast gate) the simulation phase must
  have stayed vectorized — any per-job event-engine fallback fails.
* fmax suite, converged runs (``fmax_suite.py --converge``, JSON carries
  ``"converge": true``): the same fmax/deadlock/violation gates against the
  *non-converged* baseline (the converged anchors include the discrete
  sweep, so the frontier can only match or beat it), plus the floorplan
  memoization proof — the ``sim.floorplan`` counters must show cache hits
  > 0 and strictly fewer ILP-backed solves than points evaluated.  The
  one-array-sweep rule is waived (each refine round is its own batch), but
  the padded array backend must have run at least once and a per-job
  cycle-engine fallback still fails.
* fmax suite, parallel converged runs (BOTH JSONs carry ``"converge":
  true`` — CI passes the ``--jobs 2`` run as *current* and the fresh
  sequential converged run as *baseline*): the worker pool's contract is
  bit-identical results, so every per-design row must match the sequential
  run EXACTLY (fmax, util, frontier size, hypervolume, rounds,
  points evaluated — no tolerance), and the ``sim.pool`` block must record
  the worker/merge counters (jobs >= 2, merged == dispatched) proving the
  solves really ran in subprocesses and were merged back.
* fmax suite, jax-backend runs (``fmax_suite.py --backend jax``, JSON
  carries ``"backend": "jax"`` and CI passes the fresh ``--backend
  numpy`` JSON as *baseline*): the jitted sweep's contract is bit-exact
  identity with the NumPy oracle, so every shared row field must match
  EXACTLY (no tolerance), the ``jax`` engine counter must show the sweep
  actually ran, every row's ``backend_used`` must be ``jax-padded``, and
  any ``numpy``/``event``/``cycle`` invocation or ``fallback`` tick —
  a silent degrade out of the jitted path — fails.
* fmax suite, chaos runs (``benchmarks/chaos_suite.py`` — the resumed
  JSON carries a ``chaos`` block and CI passes the drill's clean
  converged run as *baseline*): injected faults must be *survived
  invisibly* — every per-design row bit-identical to the clean run (the
  parallel-identity gate), the kill really delivered (``kill_returncode
  == -SIGKILL``) and at least one design provably resumed from its
  journal (``resumed_rounds > 0``), the pool counters nonzero where the
  plan guarantees activity (``retried``/``pool_rebuilds``, injected
  ``worker_crash``/``worker_hang``/``torn_write``), the reopened store
  quarantined the torn entries, and — because the plan keeps every fault
  transient — nothing was quarantined in the *pool* (a poison-point
  verdict would legitimately move the frontier, so its absence is part
  of the identity contract).
* fmax suite, any run with a ``sim.store`` block (``--store``): the
  determinism invariant ``conflicts == 0`` always holds (a conflict
  means two processes solved the same key to different answers), and
  outside chaos runs ``quarantined == 0`` — torn entries on a healthy
  run mean the atomic-write path regressed.
* throughput suite: per-design TAPA cycle counts must not grow more than
  ``--tol`` relative to baseline; every baseline design must still be
  present; the vectorization gate always applies (the throughput suite is
  itself the CI fast suite).
* both suites: any run with a ``sim`` block must also record the static
  pre-flight counters (``sim.analysis`` from ``repro.analysis``) with
  ``analyzed > 0`` — an absent or all-zero block means the verifier gate
  silently stopped running.  If the gate *skipped* candidates
  (``analysis.skipped > 0``) in a like-for-like comparison, every
  per-design frontier size must match the baseline exactly: skipping is
  only sound when it provably cannot move the frontier.

* corpus suite (``corpus_suite.py --json``): every generated clean-family
  design lints clean, the differential oracle table ran every stage with
  zero mismatches, zero silent backend fallbacks, every baseline search
  bucket's frontier hypervolume within ``--tol``, and the HBM
  channel-binding axis exercised by at least one bucket.

* all suites, instrumented runs (any JSON with an ``obs`` block from
  ``repro.obs``): the run must have recorded spans (zero means the
  profiling hooks silently stopped firing), closed every span, orphaned
  no worker spans, and covered >= 90% of wall time with stage spans; a
  ``--trace`` export (``trace_file``, resolved next to the BENCH JSON)
  must pass Chrome/Perfetto trace_event schema validation — monotonic
  per-track timestamps, matched B/E pairs, pid/tid on every event.

Usage:
    python benchmarks/check_regression.py CURRENT.json BASELINE.json [--tol 0.02]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _validate_trace(doc: dict) -> list[str]:
    """Schema-validate a Chrome/Perfetto trace_event document via
    ``repro.obs.trace.validate_chrome``; resolves ``src/`` relative to this
    file so the gate works without PYTHONPATH."""
    try:
        from repro.obs.trace import validate_chrome
    except ImportError:
        here = os.path.dirname(os.path.abspath(__file__))
        sys.path.insert(0, os.path.join(here, "..", "src"))
        from repro.obs.trace import validate_chrome
    return validate_chrome(doc)


def check_obs(cur: dict, *, label: str, json_dir: str = ".") -> list[str]:
    """The observability gate, applied to every instrumented run.

    An instrumented run (one whose JSON carries an ``obs`` block) must have
    recorded at least one span, closed every span it opened, parented every
    worker span back under the dispatching process, and covered >= 90% of
    the suite's wall clock with stage spans — a zero-span or low-coverage
    block means the profiling hooks silently stopped firing.  When the run
    exported a ``--trace`` file, the file (resolved relative to the BENCH
    JSON) must additionally pass trace_event schema validation: monotonic
    per-track timestamps, matched B/E pairs, pid/tid on every event."""
    sim = cur.get("sim")
    obs = sim.get("obs") if isinstance(sim, dict) else None
    if obs is None:
        obs = cur.get("obs")  # the corpus suite keeps its block top-level
    if obs is None:
        return []
    errors = []
    if not obs.get("enabled", False):
        errors.append(f"{label} obs block present but tracing was disabled")
    if not obs.get("spans", 0):
        errors.append(
            f"{label} instrumented run recorded zero spans — the profiling "
            f"hooks silently stopped firing"
        )
    if obs.get("unclosed", 0):
        errors.append(
            f"{label} trace has {obs['unclosed']} unclosed span(s) — an "
            f"instrumented stage exited without ending its span"
        )
    if obs.get("orphans", 0):
        errors.append(
            f"{label} trace has {obs['orphans']} orphaned worker span(s) "
            f"(parent id missing from the trace)"
        )
    coverage = obs.get("stage_coverage", 0.0)
    if obs.get("spans", 0) and coverage < 0.9:
        errors.append(
            f"{label} stage spans cover only {coverage:.0%} of wall time "
            f"(expected >= 90%; the hot path is escaping instrumentation)"
        )
    trace_file = obs.get("trace_file")
    if trace_file:
        path = os.path.join(json_dir, trace_file)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            errors.append(f"{label} trace file {trace_file} unreadable: {exc}")
        else:
            for err in _validate_trace(doc):
                errors.append(f"{label} trace {trace_file}: {err}")
    return errors


def check_sim(cur: dict, *, label: str) -> list[str]:
    """The CI vectorization gate, shared by both suites: the whole suite's
    simulation phase (top-level ``sim`` metadata from
    ``timed_pool_simulations``) must stay batched on the padded array
    backend — a per-job event-engine fallback or a split into several
    array-sweeps means the perf win silently evaporated."""
    sim = cur.get("sim")
    if sim is None:
        return []
    errors = []
    counts = sim.get("counts", {})
    for eng in ("event", "cycle"):
        runs = counts.get(eng, 0)
        if runs:
            errors.append(
                f"{label} fell back to per-job {eng} simulation "
                f"({runs} {eng}-engine run(s); expected 0)"
            )
    if counts.get("fallback", 0):
        errors.append(
            f"{label} recorded {counts['fallback']} silent backend "
            f"fallback(s) (expected 0)"
        )
    array_runs = counts.get("numpy", 0) + counts.get("jax", 0)
    if array_runs != 1:
        # 0 means the simulation phase silently never ran; >1 means the
        # suite degraded into several array-sweeps
        errors.append(
            f"{label} ran {array_runs} array-sweeps (expected exactly one "
            f"per suite)"
        )
    declared = cur.get("backend") or sim.get("backend")
    if declared in ("numpy", "jax"):
        other = "jax" if declared == "numpy" else "numpy"
        if counts.get(other, 0):
            errors.append(
                f"{label} declared backend={declared} but ran "
                f"{counts[other]} {other} sweep(s)"
            )
    return errors


def check_converged_sim(cur: dict, *, label: str) -> list[str]:
    """The converged-mode gate: prove the floorplan memoization fired.

    A converged run without cache hits (or with one ILP solve per point)
    means the refine rounds silently degraded to cold re-solving — the
    exact cost the ``FloorplanCache`` exists to remove.  The per-round
    batches must also have reached the padded array backend at least once
    (``numpy`` invocations > 0): the real degrade path is per-job *event*
    simulation, which is legitimate only for stray single-job rounds, so
    the gate checks the array backend ran rather than that event never
    did."""
    sim = cur.get("sim")
    if sim is None:
        return []
    errors = []
    counts = sim.get("counts", {})
    if counts.get("cycle", 0):
        errors.append(
            f"{label} fell back to per-job cycle simulation "
            f"({counts['cycle']} run(s); expected 0)"
        )
    if counts.get("fallback", 0):
        errors.append(
            f"{label} recorded {counts['fallback']} silent backend "
            f"fallback(s) (expected 0)"
        )
    if not (counts.get("numpy", 0) + counts.get("jax", 0)):
        errors.append(
            f"{label} never reached a padded array backend "
            f"(0 numpy/jax array-sweeps; per-round batches degraded to "
            f"per-job event simulation)"
        )
    fp = sim.get("floorplan", {})
    if fp.get("cache_hits", 0) <= 0:
        errors.append(
            f"{label} recorded no floorplan cache hits — memoization "
            f"silently dead"
        )
    points = sim.get("points_evaluated", 0)
    if points and fp.get("solved", 0) >= points:
        errors.append(
            f"{label} solved {fp.get('solved', 0)} floorplans for "
            f"{points} points evaluated (expected strictly fewer)"
        )
    return errors


def check_analysis(cur: dict, base: dict, *, label: str) -> list[str]:
    """The static pre-flight verifier's own gate (``repro.analysis``).

    A run that simulated anything must show the analyzer actually ran
    (``sim.analysis.analyzed > 0`` — the vacuous all-zero pass is closed,
    mirroring ``check_sim``'s one-array-sweep rule).  When the gate
    skipped statically-doomed candidates, the analyzer's soundness
    contract says only provably-dead work was removed, so in a
    like-for-like comparison (same converge mode on both sides) every
    per-design frontier size must still match the baseline exactly."""
    sim = cur.get("sim")
    if sim is None:
        return []
    errors = []
    ana = sim.get("analysis")
    if not ana or not ana.get("analyzed", 0):
        errors.append(
            f"{label} records no static-analysis activity "
            f"(sim.analysis.analyzed is 0 or missing; the pre-flight "
            f"verifier gate silently stopped running)"
        )
        return errors
    if ana.get("skipped", 0) and cur.get("converge") == base.get("converge"):
        cur_rows = {_row_key(r): r for r in cur["rows"]}
        for r in base["rows"]:
            got = cur_rows.get(_row_key(r))
            if got is None or "frontier" not in r:
                continue
            if got.get("frontier") != r.get("frontier"):
                errors.append(
                    f"design {_row_key(r)} frontier size changed "
                    f"{r.get('frontier')!r} -> {got.get('frontier')!r} in a "
                    f"run where the static gate skipped "
                    f"{ana['skipped']} candidate(s) — skipping must not "
                    f"move the frontier"
                )
    return errors


def _row_key(row: dict):
    return (row["name"], row["board"]) if "board" in row else row["name"]


#: converged-row fields the parallel run must reproduce bit-identically
PARALLEL_IDENTITY_FIELDS = (
    "opt_mhz",
    "util",
    "frontier",
    "hypervolume",
    "rounds_run",
    "points_evaluated",
    "cycles_opt",
    "cycles_base",
)


def check_parallel_frontier(cur: dict, base: dict) -> list[str]:
    """The ``--jobs N`` gate: a parallel converged run vs the sequential
    converged run it must reproduce.

    The worker pool only relocates deterministic ILP solves, so any row
    difference — however small — means the parallel path diverged from the
    sequential one and the bit-identity contract is broken; no tolerance
    applies.  The ``sim.pool`` counters must additionally prove work
    actually went through the pool and every worker result was merged
    back."""
    errors = []
    pool = cur.get("sim", {}).get("pool")
    if not pool:
        errors.append("parallel run's sim block records no pool counters")
    else:
        if pool.get("jobs", 1) < 2:
            errors.append(
                f"parallel run recorded jobs={pool.get('jobs', 1)} "
                f"(expected >= 2)"
            )
        if pool.get("merged", 0) != pool.get("dispatched", 0):
            errors.append(
                f"pool merged {pool.get('merged', 0)} of "
                f"{pool.get('dispatched', 0)} dispatched worker results"
            )
        if pool.get("dispatched", 0) and not pool.get("worker_solves", 0):
            errors.append(
                "pool dispatched work but recorded no worker-side solves"
            )
    cur_rows = {(r["name"], r["board"]): r for r in cur["rows"]}
    for r in base["rows"]:
        key = (r["name"], r["board"])
        got = cur_rows.get(key)
        if got is None:
            errors.append(f"design {key} missing from parallel run")
            continue
        for field in PARALLEL_IDENTITY_FIELDS:
            if field not in r and field not in got:
                continue
            if got.get(field) != r.get(field):
                errors.append(
                    f"{key} {field} diverged under --jobs: sequential "
                    f"{r.get(field)!r} vs parallel {got.get(field)!r} "
                    f"(bit-identity contract broken)"
                )
    return errors


#: row fields the jax-backend run must reproduce bit-exactly vs the fresh
#: NumPy-backend run (everything except wall time and the engine label)
JAX_IDENTITY_FIELDS = (
    "tasks",
    "streams",
    "base_mhz",
    "base_fail",
    "opt_mhz",
    "opt_fail",
    "util",
    "buffer_overhead_bits",
    "frontier",
    "cycles_base",
    "cycles_opt",
    "cycles_delta",
    "sim_deadlock",
    "throughput_preserved",
)


def check_jax_backend(cur: dict, base: dict) -> list[str]:
    """The ``--backend jax`` gate: a jitted-sweep run vs the fresh NumPy
    run it must reproduce.

    The jax backend's contract is bit-exact identity with the NumPy
    oracle (same padded layout, same firing rule, same deadlock
    semantics), so any row difference — however small — breaks the
    contract; no tolerance applies.  The engine counters must prove the
    jitted sweep actually ran AND that nothing silently degraded out of
    it: one ``numpy``/``event``/``cycle`` invocation or ``fallback``
    tick means the speedup being benchmarked quietly never happened."""
    errors = []
    sim = cur.get("sim") or {}
    counts = sim.get("counts", {})
    if not counts.get("jax", 0):
        errors.append("jax run recorded no jitted array-sweep (sim.counts.jax == 0)")
    for eng in ("numpy", "event", "cycle"):
        runs = counts.get(eng, 0)
        if runs:
            errors.append(
                f"jax run silently degraded to the {eng} engine "
                f"({runs} run(s); expected 0)"
            )
    if counts.get("fallback", 0):
        errors.append(
            f"jax run recorded {counts['fallback']} silent backend "
            f"fallback(s) (expected 0)"
        )
    if counts.get("jax", 0) and not sim.get("jit_cache"):
        errors.append("jax run's sim block records no jit_cache compile/hit counters")
    cur_rows = {(r["name"], r["board"]): r for r in cur["rows"]}
    for r in cur["rows"]:
        if "backend_used" in r and r["backend_used"] != "jax-padded":
            errors.append(
                f"design {(r['name'], r['board'])} scored on engine "
                f"{r['backend_used']!r} (expected 'jax-padded')"
            )
    for r in base["rows"]:
        key = (r["name"], r["board"])
        got = cur_rows.get(key)
        if got is None:
            errors.append(f"design {key} missing from jax run")
            continue
        for field in JAX_IDENTITY_FIELDS:
            if field not in r and field not in got:
                continue
            if got.get(field) != r.get(field):
                errors.append(
                    f"{key} {field} diverged under --backend jax: numpy "
                    f"{r.get(field)!r} vs jax {got.get(field)!r} "
                    f"(bit-exact contract broken)"
                )
    return errors


def check_store(cur: dict, *, label: str) -> list[str]:
    """The disk-store invariants, gated on every run that used one.

    ``conflicts`` counts concurrent writers that solved the same key to
    *different* values — ``floorplan()`` is deterministic, so any conflict
    is a correctness bug, chaos or not.  ``quarantined`` counts torn/
    corrupt blobs swept aside; on a healthy (non-chaos) run the atomic
    write-rename protocol makes that impossible, so nonzero means the
    persistence path regressed."""
    store = cur.get("sim", {}).get("store")
    if store is None:
        return []
    errors = []
    if store.get("conflicts", 0):
        errors.append(
            f"{label} store recorded {store['conflicts']} write "
            f"conflict(s) — concurrent solves of the same key disagreed "
            f"(determinism broken)"
        )
    if not cur.get("chaos") and store.get("quarantined", 0):
        errors.append(
            f"{label} store quarantined {store['quarantined']} entr(ies) "
            f"without fault injection — atomic writes are tearing"
        )
    return errors


def check_chaos(cur: dict, base: dict) -> list[str]:
    """The chaos-drill gate: a fault-injected, killed-and-resumed converged
    run vs the clean run it must reproduce (``benchmarks/chaos_suite.py``).

    Row identity is delegated to ``check_parallel_frontier`` by the
    caller; this check proves the drill actually drilled: the mid-suite
    SIGKILL was delivered, at least one design resumed from its journal
    rather than restarting, the injected faults really fired (injected
    counters) and really bit (retries, pool rebuilds, store quarantines)
    — and none of it escalated to a pool quarantine, which would have
    (legitimately) moved the frontier and broken identity."""
    errors = []
    chaos = cur.get("chaos") or {}
    if chaos.get("kill_returncode", 0) >= 0:
        errors.append(
            f"chaos run records kill_returncode="
            f"{chaos.get('kill_returncode')!r} (expected a death by "
            f"signal, i.e. negative)"
        )
    if not chaos.get("resumed"):
        errors.append(
            "chaos run never resumed a checkpoint journal (no design row "
            "has resumed_rounds > 0) — the kill-resume path went untested"
        )
    if not any(r.get("resumed_rounds", 0) > 0 for r in cur.get("rows", ())):
        errors.append(
            "chaos block claims a resume but no row records "
            "resumed_rounds > 0"
        )
    faults = cur.get("sim", {}).get("faults") or {}
    if not faults.get("plan"):
        errors.append("chaos run's sim.faults block records no fault plan")
    injected = faults.get("injected", {})
    for site in ("worker_crash", "worker_hang", "torn_write"):
        if injected.get(site, 0) <= 0:
            errors.append(
                f"chaos run injected no {site} faults — the drill is "
                f"vacuous for that failure mode"
            )
    obs = faults.get("observed", {})
    if obs.get("retried", 0) <= 0:
        errors.append(
            "chaos run recorded no pool retries — injected faults were "
            "never survived via re-dispatch"
        )
    if obs.get("pool_rebuilds", 0) <= 0:
        errors.append(
            "chaos run recorded no pool rebuilds — worker crashes never "
            "reached the BrokenProcessPool recovery path"
        )
    if obs.get("store_quarantined", 0) <= 0:
        errors.append(
            "chaos run quarantined no store entries — torn writes were "
            "injected but the reopened store never detected them"
        )
    if obs.get("quarantined", 0):
        errors.append(
            f"chaos run quarantined {obs['quarantined']} point(s) in the "
            f"pool — the plan keeps faults transient, so a poison-point "
            f"verdict means retry accounting broke (and row identity is "
            f"void)"
        )
    if obs.get("merge_conflicts", 0):
        errors.append(
            f"chaos run recorded {obs['merge_conflicts']} cache merge "
            f"conflict(s) — worker results disagreed with the parent's "
            f"(determinism broken)"
        )
    return errors


def check_fmax(cur: dict, base: dict, tol: float, *, json_dir: str = ".") -> list[str]:
    errors = []
    cs, bs = cur["summary"], base["summary"]
    floor = bs["opt_avg_mhz"] * (1.0 - tol)
    if cs["opt_avg_mhz"] < floor:
        errors.append(
            f"avg optimized fmax regressed: {cs['opt_avg_mhz']:.1f} MHz "
            f"< {floor:.1f} MHz (baseline {bs['opt_avg_mhz']:.1f}, tol {tol:.0%})"
        )
    if cs.get("sim_deadlocks", 0):
        errors.append(f"{cs['sim_deadlocks']} design(s) deadlocked in simulation")
    if cs.get("throughput_violations", 0):
        errors.append(
            f"{cs['throughput_violations']} design(s) lost steady-state throughput"
        )
    if cur.get("chaos"):
        # chaos drill: fault-injected killed-and-resumed run vs clean run —
        # exact row identity plus proof the faults fired and were survived
        errors += check_converged_sim(cur, label="chaos run")
        errors += check_parallel_frontier(cur, base)
        errors += check_chaos(cur, base)
    elif cur.get("converge") and base.get("converge"):
        # parallel-vs-sequential converged comparison: exact identity
        errors += check_converged_sim(cur, label="converged run")
        errors += check_parallel_frontier(cur, base)
    elif cur.get("converge"):
        errors += check_converged_sim(cur, label="converged run")
    elif cur.get("backend") == "jax" and base.get("backend") != "jax":
        # jax-vs-numpy backend comparison: exact identity
        errors += check_sim(cur, label="jax backend run")
        errors += check_jax_backend(cur, base)
    elif cur.get("subset"):
        errors += check_sim(cur, label="fast subset")
    errors += check_analysis(cur, base, label="fmax suite")
    errors += check_store(cur, label="fmax suite")
    errors += check_obs(cur, label="fmax suite", json_dir=json_dir)
    cur_rows = {(r["name"], r["board"]): r for r in cur["rows"]}
    for r in base["rows"]:
        key = (r["name"], r["board"])
        if key not in cur_rows:
            errors.append(f"design {key} missing from current run")
            continue
        if r["opt_mhz"] > 0 and cur_rows[key]["opt_mhz"] == 0:
            errors.append(f"design {key} became unroutable")
    return errors


def check_throughput(
    cur: dict, base: dict, tol: float, *, json_dir: str = "."
) -> list[str]:
    # the throughput suite IS the CI fast suite: always gate vectorization
    errors = check_sim(cur, label="throughput suite")
    errors += check_analysis(cur, base, label="throughput suite")
    errors += check_store(cur, label="throughput suite")
    errors += check_obs(cur, label="throughput suite", json_dir=json_dir)
    cur_rows = {r["name"]: r for r in cur["rows"]}
    for r in base["rows"]:
        name = r["name"]
        if name not in cur_rows:
            errors.append(f"design {name} missing from current run")
            continue
        ceiling = r["cycles_tapa"] * (1.0 + tol)
        got = cur_rows[name]["cycles_tapa"]
        if got > ceiling:
            errors.append(
                f"{name}: simulated cycles regressed {r['cycles_tapa']} -> {got} "
                f"(tol {tol:.0%})"
            )
    return errors


def check_corpus(
    cur: dict, base: dict, tol: float, *, json_dir: str = "."
) -> list[str]:
    """The generated-corpus gate (``benchmarks/corpus_suite.py``):

    * every clean-family design lints clean (zero structure errors);
    * the differential harness found no oracle mismatch, actually covered
      every stage (verdicts, backend equivalence, both autobridge paths,
      parallel-search identity), and the corpus is at least as large as
      the baseline's;
    * zero silent backend fallbacks across the whole suite;
    * every baseline search bucket is still present with frontier
      hypervolume within ``--tol`` of the committed value (search power
      on generated topologies must not regress);
    * the HBM channel-binding axis was exercised by at least one bucket.
    """
    errors = []
    lint = cur.get("lint", {})
    if not lint.get("checked"):
        errors.append("corpus suite recorded no linted designs")
    if lint.get("errors"):
        errors.append(
            f"{lint['errors']} corpus design(s) failed structure lint "
            f"(codes: {', '.join(lint.get('codes', []) or ['?'])})")
    diff = cur.get("differential", {})
    if not diff.get("ok", False):
        for m in diff.get("mismatches", [])[:10]:
            errors.append(f"differential mismatch: {m}")
        if not diff.get("mismatches"):
            errors.append("differential harness did not report ok")
    base_diff = base.get("differential", {})
    if diff.get("designs", 0) < base_diff.get("designs", 0):
        errors.append(
            f"corpus shrank: {diff.get('designs', 0)} designs vs baseline "
            f"{base_diff.get('designs', 0)}")
    for counter in ("verdicts_checked", "sims_checked", "feasible",
                    "infeasible", "searches_checked"):
        if not diff.get(counter):
            errors.append(
                f"differential stage never ran: {counter} == 0")
    if cur.get("engine", {}).get("fallback", 0):
        errors.append(
            f"corpus suite recorded {cur['engine']['fallback']} silent "
            f"backend fallback(s) (expected 0)")
    cur_buckets = {b["design"]: b for b in cur.get("buckets", [])}
    for b in base.get("buckets", []):
        got = cur_buckets.get(b["design"])
        if got is None:
            errors.append(f"search bucket {b['design']} missing")
            continue
        floor = b["hypervolume"] * (1.0 - tol)
        if got["hypervolume"] < floor:
            errors.append(
                f"{b['design']}: frontier hypervolume regressed "
                f"{b['hypervolume']:.4g} -> {got['hypervolume']:.4g} "
                f"(tol {tol:.0%})")
    if not any(b.get("hbm_axis") for b in cur.get("buckets", [])):
        errors.append(
            "no search bucket exercised the HBM channel-binding axis")
    errors += check_obs(cur, label="corpus suite", json_dir=json_dir)
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.02,
        help="relative tolerance on the gated metric (default 2%%)",
    )
    args = ap.parse_args(argv)

    cur, base = _load(args.current), _load(args.baseline)
    json_dir = os.path.dirname(os.path.abspath(args.current))
    if cur.get("suite") != base.get("suite"):
        print(
            f"suite mismatch: current={cur.get('suite')} baseline={base.get('suite')}"
        )
        return 2
    if cur.get("suite") == "fmax_suite":
        errors = check_fmax(cur, base, args.tol, json_dir=json_dir)
    elif cur.get("suite") == "throughput":
        errors = check_throughput(cur, base, args.tol, json_dir=json_dir)
    elif cur.get("suite") == "corpus":
        errors = check_corpus(cur, base, args.tol, json_dir=json_dir)
    else:
        print(f"unknown suite {cur.get('suite')!r}")
        return 2

    if errors:
        print(f"REGRESSION ({len(errors)} finding(s)) vs {args.baseline}:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"OK: {args.current} within {args.tol:.0%} of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
