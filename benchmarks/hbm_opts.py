"""Paper §7.4 Tables 8-10: HBM-specific optimizations, channel-aware.

  Table 3 analogue : async_mmap vs mmap resource cost per channel
                     (the per-channel math uses ``U280_HBM_CHANNELS``
                     from ``repro.fpga`` — the board owns the constant)
  Tables 8/9       : the 5 HBM designs, mmap+packed vs async+TAPA
  Table 10         : multi-floorplan generation — the util sweep joined
                     with the HBM channel-binding axis
                     (``SearchSpace.hbm_splits``), so each design's
                     Pareto frontier spans bindings as well as head-room

``--json`` writes a ``BENCH_hbm_opts.json`` row dump (the nightly runs
it; ``run.py`` keeps consuming the CSV lines).
"""
from __future__ import annotations

import argparse
import json

from repro.core import (InfeasibleError, analyze_timing, autobridge,
                        packed_placement)
from repro.fpga import U280_HBM_CHANNELS, benchmarks as B, u280_grid
from repro.fpga.benchmarks import ASYNC_IO, MMAP_IO
from repro.search.engine import explore_design_space
from repro.search.pareto import hypervolume, objective_vector
from repro.search.space import SearchSpace

#: the channel-binding sweep of the Table 10 frontier (0.5 = the
#: platform's symmetric default binding)
HBM_SPLITS = (0.25, 0.5, 0.75)
UTILS = (0.6, 0.65, 0.7, 0.75, 0.8, 0.85)
#: fixed hypervolume reference, same convention as ``corpus_suite``
HV_REF = (0.0, -200_000.0, -10_000.0)


def _builders():
    return {"sasa_v1": lambda a: B.sasa(1, a),
            "sasa_v2": lambda a: B.sasa(2, a),
            "spmm": B.spmm,
            "spmv_a16": lambda a: B.spmv(20, a),
            "spmv_a24": lambda a: B.spmv(28, a)}


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args(argv)
    rows: list[dict] = []

    bram_saved = U280_HBM_CHANNELS * MMAP_IO["BRAM"]
    print(f"hbm_opts,table3,0,mmap=LUT{MMAP_IO['LUT']:.0f}/"
          f"FF{MMAP_IO['FF']:.0f}/BRAM{MMAP_IO['BRAM']:.0f} "
          f"async=LUT{ASYNC_IO['LUT']:.0f}/FF{ASYNC_IO['FF']:.0f}/"
          f"BRAM{ASYNC_IO['BRAM']:.0f} "
          f"bram_saved_{U280_HBM_CHANNELS}ch={bram_saved:.0f}")
    rows.append({"table": "table3", "name": "per_channel_io",
                 "mmap": dict(MMAP_IO), "async": dict(ASYNC_IO),
                 "channels": U280_HBM_CHANNELS,
                 "bram_saved": bram_saved})

    builders = _builders()
    grid = u280_grid()
    for name, make in builders.items():
        g_mmap = make(False)
        base = analyze_timing(g_mmap, grid, packed_placement(g_mmap, grid))
        g_async = make(True)
        try:
            plan = autobridge(g_async, grid, max_util=0.8)
            opt = analyze_timing(g_async, grid, plan.floorplan.placement,
                                 plan.depth)
            opt_mhz = opt.fmax_mhz if opt.routed else None
            o = (f"{opt.fmax_mhz:.0f}/{opt.hbm_clk_mhz:.0f}MHz"
                 if opt.routed else "FAIL")
        except InfeasibleError:
            opt_mhz, o = None, "INFEAS"

        def bram(g):
            return g.total_area().get("BRAM", 0)

        bb = f"{base.fmax_mhz:.0f}/{base.hbm_clk_mhz:.0f}MHz" \
            if base.routed else "FAIL"
        print(f"hbm_opts,{name},0,orig={bb} opt={o} "
              f"bram={bram(g_mmap):.0f}->{bram(g_async):.0f}")
        rows.append({"table": "table8_9", "name": name,
                     "base_mhz": round(base.fmax_mhz, 1)
                     if base.routed else None,
                     "opt_mhz": round(opt_mhz, 1) if opt_mhz else None,
                     "bram_mmap": bram(g_mmap),
                     "bram_async": bram(g_async)})

    # Table 10: the multi-floorplan Pareto sweep, now joint with the HBM
    # channel-binding axis — each point is (util, hbm_split), and the
    # floorplan cache keys bindings apart automatically (slot_caps are
    # part of the grid signature)
    space = SearchSpace(seeds=(0,), utils=UTILS, hbm_splits=HBM_SPLITS)
    for name in ("sasa_v1", "spmm", "spmv_a24"):
        g = builders[name](True)
        res = explore_design_space(g, u280_grid(), space=space,
                                   sim_firings=100)
        ok = [c for c in res.candidates if c.plan is not None]
        vecs = [objective_vector(c) for c in res.frontier]
        hv = hypervolume(vecs, HV_REF)
        fmaxes = [c.report.fmax_mhz for c in ok if c.report.routed]
        splits = sorted({c.point.hbm_split for c in res.frontier})
        print(f"hbm_opts,multifloorplan_{name},0,"
              f"points={len(res.candidates)} feasible={len(ok)} "
              f"frontier={len(res.frontier)} "
              f"max={max(fmaxes) if fmaxes else 0:.0f}MHz "
              f"min={min(fmaxes) if fmaxes else 0:.0f}MHz "
              f"hv={hv:.3g} frontier_splits={splits}")
        rows.append({"table": "table10", "name": name,
                     "points": len(res.candidates), "feasible": len(ok),
                     "frontier": len(res.frontier),
                     "hypervolume": hv,
                     "max_mhz": round(max(fmaxes), 1) if fmaxes else None,
                     "frontier_splits": splits,
                     "hbm_splits": list(HBM_SPLITS)})

    out = {"suite": "hbm_opts", "rows": rows}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json_path}", flush=True)
    return out


if __name__ == "__main__":
    main()
