"""Paper §7.4 Tables 8-10: HBM-specific optimizations.

  Table 3 analogue : async_mmap vs mmap resource cost per channel
  Tables 8/9       : the 5 HBM designs, mmap+packed vs async+TAPA
  Table 10         : multi-floorplan generation (util sweep, all points)
"""
from __future__ import annotations

from repro.core import (InfeasibleError, analyze_timing, autobridge,
                        explore_floorplans, packed_placement)
from repro.fpga import benchmarks as B, u280_grid
from repro.fpga.benchmarks import ASYNC_IO, MMAP_IO


def main():
    print(f"hbm_opts,table3,0,mmap=LUT{MMAP_IO['LUT']:.0f}/"
          f"FF{MMAP_IO['FF']:.0f}/BRAM{MMAP_IO['BRAM']:.0f} "
          f"async=LUT{ASYNC_IO['LUT']:.0f}/FF{ASYNC_IO['FF']:.0f}/"
          f"BRAM{ASYNC_IO['BRAM']:.0f} "
          f"bram_saved_32ch={32*MMAP_IO['BRAM']:.0f}")

    builders = {"sasa_v1": lambda a: B.sasa(1, a),
                "sasa_v2": lambda a: B.sasa(2, a),
                "spmm": B.spmm,
                "spmv_a16": lambda a: B.spmv(20, a),
                "spmv_a24": lambda a: B.spmv(28, a)}
    grid = u280_grid()
    for name, make in builders.items():
        g_mmap = make(False)
        base = analyze_timing(g_mmap, grid, packed_placement(g_mmap, grid))
        g_async = make(True)
        try:
            plan = autobridge(g_async, grid, max_util=0.8)
            opt = analyze_timing(g_async, grid, plan.floorplan.placement,
                                 plan.depth)
            o = (f"{opt.fmax_mhz:.0f}/{opt.hbm_clk_mhz:.0f}MHz"
                 if opt.routed else "FAIL")
        except InfeasibleError:
            o = "INFEAS"
        def bram(g):
            return g.total_area().get("BRAM", 0)
        bb = f"{base.fmax_mhz:.0f}/{base.hbm_clk_mhz:.0f}MHz" \
            if base.routed else "FAIL"
        print(f"hbm_opts,{name},0,orig={bb} opt={o} "
              f"bram={bram(g_mmap):.0f}->{bram(g_async):.0f}")

    # Table 10: the multi-floorplan Pareto sweep
    for name in ("sasa_v1", "spmm", "spmv_a24"):
        g = builders[name](True)
        cands = explore_floorplans(g, u280_grid(),
                                   utils=(0.6, 0.65, 0.7, 0.75, 0.8, 0.85))
        pts = "/".join(f"{c.fmax:.0f}" if c.plan and c.report.routed
                       else "Failed" for c in cands)
        ok = [c.fmax for c in cands if c.plan and c.report.routed]
        print(f"hbm_opts,multifloorplan_{name},0,points={pts}MHz "
              f"max={max(ok) if ok else 0:.0f} min={min(ok) if ok else 0:.0f}")


if __name__ == "__main__":
    main()
