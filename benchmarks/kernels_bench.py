"""Per-kernel microbenchmarks: interpret-mode walltime is meaningless for
TPU perf, so we report the kernel's analytic arithmetic intensity and the
reference-vs-kernel agreement, plus the jnp-reference XLA walltime on CPU
(useful as a relative regression signal)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def timeit(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def main():
    key = jax.random.PRNGKey(0)
    B, S, H, D = 1, 512, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H // 2, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H // 2, D), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = timeit(fa, q, k, v)
    flops = 4 * B * S * S * H * D / 2
    print(f"kernels,flash_attention_ref,{us:.0f},"
          f"ai={flops/(3*q.size*4):.1f}flop/B")

    x = jax.random.normal(key, (B, S, H, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)))
    Bm = jax.random.normal(key, (B, S, 64))
    Cm = jax.random.normal(key, (B, S, 64))
    m2 = jax.jit(lambda *a: ref.mamba2_scan_ref(*a))
    us = timeit(m2, x, dt, A, Bm, Cm)
    print(f"kernels,mamba2_scan_ref,{us:.0f},seq={S}")

    r6in = [jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
            for i in range(3)]
    w = jnp.exp(-jnp.exp(jax.random.normal(key, (B, S, H, D))))
    u = 0.3 * jax.random.normal(key, (H, D))
    r6 = jax.jit(lambda *a: ref.rwkv6_scan_ref(*a))
    us = timeit(r6, *r6in, w, u)
    print(f"kernels,rwkv6_scan_ref,{us:.0f},seq={S}")

    table = jax.random.normal(key, (65536, 512), jnp.float32)
    idx = jnp.arange(2048, dtype=jnp.int32)
    bg = jax.jit(ref.burst_gather_ref)
    us = timeit(bg, table, idx)
    print(f"kernels,burst_gather_ref,{us:.0f},rows=2048 seq_pattern=1.0")

    T, K, N, E = 2048, 512, 512, 8
    xg = jax.random.normal(key, (T, K), jnp.float32)
    wg = jax.random.normal(key, (E, K, N), jnp.float32) * 0.05
    gid = jnp.sort(jax.random.randint(key, (T,), 0, E))
    gm = jax.jit(ref.moe_gmm_ref)
    us = timeit(gm, xg, wg, gid)
    print(f"kernels,moe_gmm_ref,{us:.0f},T={T} E={E}")


if __name__ == "__main__":
    main()
